"""Shared model layers: RMSNorm, RoPE, GQA attention, gated MLPs.

Attention is a pure-JAX flash/online-softmax implementation (lax.scan over
KV blocks, fp32 accumulators): full-sequence training at 4k and prefill at
32k would otherwise materialize O(S^2) score tensors that cannot fit HBM.
Supports causal masking, sliding windows (mixtral/gemma2/hymba), attention
logit softcapping (gemma2), cross-attention (whisper), and KV-length masking
(decode with a partially filled cache). Decode (Sq==1) uses a direct path —
one token's scores over the cache are cheap and GSPMD shards them cleanly.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def scan_unroll():
    """Full-unroll switch for cost calibration (see launch/dryrun.py).

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count; the dry-run sets REPRO_UNROLL_SCANS=1 on small-L variants to get
    fully-counted FLOPs/bytes/collectives and extrapolates to the real L.
    """
    return os.environ.get("REPRO_UNROLL_SCANS", "") not in ("", "0")


# ---------------------------------------------------------------------------
# Basic blocks
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def swiglu(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ wg) * (x @ wi)
    return h @ wo


def geglu(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ wg, approximate=True) * (x @ wi)
    return h @ wo


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, inv_freq: jax.Array) -> jax.Array:
    """Interleaved-pair RoPE. x: (B, S, H, hd); positions: (S,) or (B, S).

    Interleaved (GPT-NeoX original) rather than rotate-half: rotation pairs
    are *adjacent* channels (2i, 2i+1), so when head_dim is sharded over the
    ``model`` axis (the kv-heads < TP-degree fallback, see sharding/specs.py)
    both members of a pair live on the same device and RoPE needs no
    cross-device traffic. Mathematically equivalent up to a fixed channel
    permutation (init is iid random, so the permutation is immaterial).
    """
    dtype = x.dtype
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x = x.astype(jnp.float32)
    shape = x.shape
    x = x.reshape(*shape[:-1], shape[-1] // 2, 2)
    x1, x2 = x[..., 0], x[..., 1]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(shape)
    return out.astype(dtype)


def sinusoidal_positions(num_positions: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal absolute position embeddings."""
    pos = jnp.arange(num_positions, dtype=jnp.float32)[:, None]
    inv = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim
    )[None, :]
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _mask_scores(
    s: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    causal: bool,
    window: jax.Array | int | None,
    kv_len: jax.Array | int | None,
) -> jax.Array:
    """s: (..., Sq, Tb); q_pos: (Sq,); k_pos: (Tb,)."""
    valid = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        valid &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        # Attend to at most `window` previous positions (inclusive of self).
        valid &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        valid &= (k_pos < kv_len)[None, :]
    return jnp.where(valid, s, NEG_INF)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: jax.Array | int | None = None,
    attn_softcap: float | None = None,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | int | None = None,
    block_k: int = 1024,
) -> jax.Array:
    """Online-softmax attention.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) with Hq % Hkv == 0 (GQA).
    Returns (B, Sq, Hq, hd) in q.dtype. Scores/accumulators are fp32.
    """
    batch, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    groups = hq // hkv
    scale = hd ** -0.5

    if sq == 1:
        return _decode_attention(
            q, k, v, causal=causal, window=window, attn_softcap=attn_softcap,
            q_offset=q_offset, kv_len=kv_len,
        )

    block_k = min(block_k, skv)
    if skv % block_k:
        # Pad KV to a block multiple; padded keys are masked via kv_len.
        pad = block_k - skv % block_k
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_len = jnp.minimum(jnp.asarray(kv_len if kv_len is not None else skv), skv)
        skv = k.shape[1]
    nblk = skv // block_k

    qg = q.reshape(batch, sq, hkv, groups, hd)
    qg = jnp.moveaxis(qg, 1, 3).astype(jnp.float32)            # (B,Hkv,G,Sq,hd)
    kb = jnp.moveaxis(k.reshape(batch, nblk, block_k, hkv, hd), 3, 2)
    vb = jnp.moveaxis(v.reshape(batch, nblk, block_k, hkv, hd), 3, 2)
    kb = jnp.moveaxis(kb, 1, 0)                             # (nblk,B,Hkv,Tb,hd)
    vb = jnp.moveaxis(vb, 1, 0)

    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)

    def body(carry, inputs):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, blk_idx = inputs                          # (B,Hkv,Tb,hd)
        s = jnp.einsum(
            "bkgqd,bktd->bkgqt", qg, k_blk.astype(jnp.float32)
        ) * scale
        if attn_softcap is not None:
            s = softcap(s, attn_softcap)
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        s = _mask_scores(
            s, q_pos, k_pos, causal=causal, window=window, kv_len=kv_len
        )
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqt,bktd->bkgqd", p, v_blk.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((batch, hkv, groups, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((batch, hkv, groups, sq), jnp.float32)
    acc0 = jnp.zeros((batch, hkv, groups, sq, hd), jnp.float32)
    (m_f, l_f, acc_f), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kb, vb, jnp.arange(nblk)),
        unroll=True if scan_unroll() else 1,
    )
    out = acc_f / jnp.maximum(l_f, 1e-30)[..., None]            # (B,Hkv,G,Sq,hd)
    out = jnp.moveaxis(out, 3, 1).reshape(batch, sq, hq, hd)
    return out.astype(q.dtype)


def _decode_attention(
    q, k, v, *, causal, window, attn_softcap, q_offset, kv_len
) -> jax.Array:
    """Direct attention for a single query position (Sq == 1)."""
    batch, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    groups = hq // hkv
    scale = hd ** -0.5
    qg = q.reshape(batch, sq, hkv, groups, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k.astype(jnp.float32)) * scale
    if attn_softcap is not None:
        s = softcap(s, attn_softcap)
    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    s = _mask_scores(s, q_pos, k_pos, causal=causal, window=window, kv_len=kv_len)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(batch, sq, hq, hd).astype(q.dtype)


def split_heads(t: jax.Array, n_heads: int, head_dim: int, layout: str) -> jax.Array:
    """(B, S, n*hd) -> (B, S, n, hd).

    layout='head': columns are head-major (standard). layout='hd': columns
    are head_dim-major — used when n_heads doesn't divide the model axis but
    head_dim does, so the projection's column sharding propagates to the
    head_dim factor of the reshape (see sharding/specs.py).
    """
    b, s, _ = t.shape
    if layout == "hd":
        return jnp.swapaxes(t.reshape(b, s, head_dim, n_heads), 2, 3)
    return t.reshape(b, s, n_heads, head_dim)


def merge_heads(t: jax.Array, layout: str) -> jax.Array:
    b, s, h, hd = t.shape
    if layout == "hd":
        return jnp.swapaxes(t, 2, 3).reshape(b, s, hd * h)
    return t.reshape(b, s, h * hd)


def attention_block(
    x: jax.Array,
    params: dict,
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    positions: jax.Array,
    inv_freq: jax.Array | None,
    causal: bool = True,
    window: jax.Array | int | None = None,
    attn_softcap: float | None = None,
    kv_cache: tuple[jax.Array, jax.Array] | None = None,
    cache_index: jax.Array | None = None,
    kv_len: jax.Array | int | None = None,
    cross_kv: jax.Array | None = None,
    block_k: int = 1024,
    q_layout: str = "head",
    kv_layout: str = "head",
):
    """Full attention sub-block: projections + rope + attention + out-proj.

    Returns (out, new_kv_cache). With ``kv_cache`` given, the fresh K/V are
    written at ``cache_index`` and attention runs over the whole cache.
    With ``cross_kv`` (B, S_enc, D) this is cross-attention (no cache/rope).
    """
    batch, sq, _ = x.shape
    kv_src = cross_kv if cross_kv is not None else x
    q = split_heads(x @ params["wq"], num_heads, head_dim, q_layout)
    k = split_heads(kv_src @ params["wk"], num_kv_heads, head_dim, kv_layout)
    v = split_heads(kv_src @ params["wv"], num_kv_heads, head_dim, kv_layout)

    if inv_freq is not None and cross_kv is None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)

    # The post-RoPE K/V are the cache content: return them even without a
    # pre-allocated buffer (prefill builds its cache from these).
    new_cache = (k, v)
    q_offset = 0
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        k, v = ck, cv
        new_cache = (ck, cv)
        q_offset = cache_index
        kv_len = cache_index + sq if kv_len is None else kv_len

    out = flash_attention(
        q, k, v,
        causal=causal and cross_kv is None,
        window=window,
        attn_softcap=attn_softcap,
        q_offset=q_offset,
        kv_len=kv_len,
        block_k=block_k,
    )
    out = merge_heads(out, q_layout) @ params["wo"]
    return out, new_cache
