"""Fused, batched Newton-Schulz: whole chains (or iterations) in one launch.

The tiled kernels in ``newton_schulz.py`` execute one NS iteration as three
chained launches (``matmul`` for the Gram matrix, two ``fma_matmul`` for the
polynomial and the update), bouncing every intermediate through HBM. This
module fuses the whole iteration

    A = X X^T;  P = bA + cA^2;  Y = aX + P X

into a single kernel: per grid step, one stacked matrix is read from HBM
into VMEM once, the Gram matrix lives in an fp32 VMEM scratch accumulator,
and only the final ``Y`` is written back — one HBM read and one HBM write
per NS iteration instead of six round-trips.

``orthogonalize(..., chain=True)`` goes one level further and runs **all K
iterations inside ONE launch** (the ``fused_chain`` dispatch strategy): X
stays resident in VMEM for the entire chain, so the K-step orthogonalization
costs one HBM read and one HBM write *total* instead of per iteration —
the per-iteration kernel round-trips X through HBM K-1 more times than
necessary whenever the block fits VMEM for the whole chain (which is the
same VMEM working set: the chain reuses the iteration's buffers in place).
The per-iteration launcher (``chain=False`` / strategy ``"fused_iter"``)
remains the A/B comparison point; ``benchmarks/ns_cost.py`` reports the
launch-count and wall-time delta.

Two structural optimizations:

  * **Batched grid.** The grid is the leading stack dimension, so one launch
    covers a whole shape bucket (see ``core/bucketing.py``) — stacked layers
    or blocks of identical shape run as a single kernel with no per-matrix
    dispatch overhead.
  * **Gram symmetry.** ``A = X X^T`` is symmetric, so the Gram stage only
    computes the upper-triangular (i <= j) tile pairs on the MXU and mirrors
    the transpose into the lower triangle — ~2x fewer Gram-stage MXU tiles.

Sizing: the per-step working set is ``m_p x n_p`` for X/Y plus two
``m_p x m_p`` fp32 Gram-sized buffers (m_p = padded small side). ``fits_vmem``
gates dispatch so oversized matrices fall back to the tiled/jnp paths.

Like the sibling kernels this file is validated in interpret mode on CPU
(``interpret=True``) against ``ref.py``; on TPU the same code lowers to
Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.newton_schulz.newton_schulz import CompilerParams, round_up

# Gram-stage tile (rows of X per MXU dot). 128 matches the MXU systolic array.
DEFAULT_GRAM_TILE = 128

# Conservative per-core VMEM budget for the fused working set (real VMEM is
# ~16 MiB/core; leave headroom for double-buffering the HBM<->VMEM streams).
VMEM_BUDGET_BYTES = 12 * 2**20

# Trace-time Pallas launch counter: every pallas_call this module issues
# bumps it once per trace. Benchmarks/tests read the delta across a fresh
# trace to demonstrate fused-chain (1 launch) vs per-iteration (K launches)
# without parsing HLO.
_launches = 0


def launch_count() -> int:
    return _launches


def _count_launch() -> None:
    global _launches
    _launches += 1


def _ns_step(x: jax.Array, gram_ref, *, a, b, c, tm, nt) -> jax.Array:
    """One NS iteration on an fp32 VMEM-resident (m_p, n_p) value.

    ``gram_ref`` is the fp32 VMEM accumulator for ``A = X X^T``; only
    upper-triangular tile pairs hit the MXU, the rest is mirrored.
    """
    for i in range(nt):
        xi = x[i * tm : (i + 1) * tm, :]
        for j in range(i, nt):
            xj = x[j * tm : (j + 1) * tm, :]
            tile = jnp.dot(xi, xj.T, preferred_element_type=jnp.float32)
            gram_ref[i * tm : (i + 1) * tm, j * tm : (j + 1) * tm] = tile
            if j > i:
                gram_ref[j * tm : (j + 1) * tm, i * tm : (i + 1) * tm] = tile.T
    gram = gram_ref[...]
    poly = b * gram + c * jnp.dot(gram, gram, preferred_element_type=jnp.float32)
    return a * x + jnp.dot(poly, x, preferred_element_type=jnp.float32)


def _fused_ns_kernel(x_ref, out_ref, gram_ref, *, a, b, c, tm, nt):
    """One full NS iteration on the (1, m_p, n_p) block in VMEM."""
    y = _ns_step(x_ref[0].astype(jnp.float32), gram_ref, a=a, b=b, c=c, tm=tm, nt=nt)
    out_ref[0] = y.astype(out_ref.dtype)


def _fused_ns_chain_kernel(x_ref, out_ref, gram_ref, *, a, b, c, tm, nt, steps):
    """ALL ``steps`` NS iterations on the (1, m_p, n_p) block, one launch.

    X never leaves VMEM between iterations — the unrolled chain reuses the
    same Gram scratch, so the whole orthogonalization is one HBM read and
    one HBM write per stacked matrix.
    """
    x = x_ref[0].astype(jnp.float32)
    for _ in range(steps):
        x = _ns_step(x, gram_ref, a=a, b=b, c=c, tm=tm, nt=nt)
    out_ref[0] = x.astype(out_ref.dtype)


def _padded_dims(m: int, n: int, tm: int) -> tuple[int, int, int]:
    """(tile, m_p, n_p): Gram tile clamped to the matrix, TPU-aligned pads."""
    tm_ = min(tm, round_up(m, 8))
    return tm_, round_up(m, tm_), round_up(n, 128)


def fits_vmem(shape, *, tm: int = DEFAULT_GRAM_TILE, budget: int = VMEM_BUDGET_BYTES) -> bool:
    """Whether the fused kernel's VMEM working set fits for ``shape``.

    Counts the fp32 X and Y blocks plus the Gram accumulator and the
    polynomial temporary (both ``m_p x m_p``), using the post-transpose
    small side as ``m``.
    """
    m, n = int(shape[-2]), int(shape[-1])
    m, n = min(m, n), max(m, n)
    tm_, mp, np_ = _padded_dims(m, n, tm)
    del tm_
    working = 4 * (2 * mp * np_ + 2 * mp * mp)
    return working <= budget


def _ns_iteration_padded(
    xp: jax.Array, a: float, b: float, c: float, tm: int, interpret: bool
) -> jax.Array:
    """Launch the fused kernel on an already tile-aligned ``(B, m_p, n_p)``."""
    bsz, mp, np_ = xp.shape
    _count_launch()
    return pl.pallas_call(
        functools.partial(_fused_ns_kernel, a=a, b=b, c=c, tm=tm, nt=mp // tm),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, mp, np_), lambda i: (i, 0, 0), memory_space=pltpu.VMEM)
        ],
        out_specs=pl.BlockSpec(
            (1, mp, np_), lambda i: (i, 0, 0), memory_space=pltpu.VMEM
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, mp, np_), xp.dtype),
        scratch_shapes=[pltpu.VMEM((mp, mp), jnp.float32)],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xp)


def _ns_chain_padded(
    xp: jax.Array, a: float, b: float, c: float, tm: int, steps: int,
    interpret: bool,
) -> jax.Array:
    """Launch the whole K-iteration chain on a tile-aligned ``(B, m_p, n_p)``.

    One ``pallas_call`` total — identical VMEM working set to the single
    iteration (X/Y block + Gram scratch + polynomial temporary), so the
    ``fits_vmem`` gate applies unchanged.
    """
    bsz, mp, np_ = xp.shape
    _count_launch()
    return pl.pallas_call(
        functools.partial(
            _fused_ns_chain_kernel, a=a, b=b, c=c, tm=tm, nt=mp // tm,
            steps=steps,
        ),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, mp, np_), lambda i: (i, 0, 0), memory_space=pltpu.VMEM)
        ],
        out_specs=pl.BlockSpec(
            (1, mp, np_), lambda i: (i, 0, 0), memory_space=pltpu.VMEM
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, mp, np_), xp.dtype),
        scratch_shapes=[pltpu.VMEM((mp, mp), jnp.float32)],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xp)


def _pad_stack(x: jax.Array, mp: int, np_: int) -> jax.Array:
    """Zero-pad the trailing dims of ``(B, m, n)`` to ``(B, m_p, n_p)``.

    Zero-padding is exact for NS: padded rows/cols of X produce zero
    rows/cols in A and in ``(bA + cA^2) X``, and ``aX`` keeps the pad zero,
    so slicing the output back recovers the unpadded result.
    """
    _, m, n = x.shape
    if (mp, np_) == (m, n):
        return x
    return jnp.pad(x, ((0, 0), (0, mp - m), (0, np_ - n)))


@functools.partial(jax.jit, static_argnames=("coeffs", "tm", "interpret"))
def ns_iteration_batched(
    x: jax.Array,
    coeffs,
    *,
    tm: int = DEFAULT_GRAM_TILE,
    interpret: bool = False,
) -> jax.Array:
    """One fused NS iteration over a stack ``(B, m, n)`` — one launch total."""
    if x.ndim != 3:
        raise ValueError(f"fused kernel expects (stack, m, n), got {x.shape}")
    a, b, c = (float(v) for v in coeffs)
    _, m, n = x.shape
    tm_, mp, np_ = _padded_dims(m, n, tm)
    out = _ns_iteration_padded(
        _pad_stack(x, mp, np_), a, b, c, tm_, interpret
    )
    return out[:, :m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("steps", "coeffs", "eps", "tm", "interpret", "chain",
                     "normalize"),
)
def orthogonalize(
    g: jax.Array,
    steps: int = 5,
    coeffs=(2.0, -1.5, 0.5),
    *,
    eps: float = 1e-7,
    tm: int = DEFAULT_GRAM_TILE,
    interpret: bool = False,
    chain: bool = False,
    normalize: bool = True,
) -> jax.Array:
    """Fused-kernel NS orthogonalization over the trailing two dims.

    Accepts arbitrary leading (stack) dims; matches
    ``core.newton_schulz.orthogonalize`` numerics — iterate on the smaller
    side, fro-normalize, fp32 internally, cast back at the end.
    ``normalize=False`` skips the entry normalization for pre-scaled inputs
    (the Turbo-Muon preconditioner path).

    ``chain=True`` runs all ``steps`` iterations inside ONE Pallas launch
    (X stays in VMEM for the whole chain); ``chain=False`` launches once
    per iteration — same numerics, K-1 extra HBM round-trips of X.
    """
    if g.ndim < 2:
        raise ValueError(f"orthogonalize expects a matrix, got shape {g.shape}")
    orig_dtype = g.dtype
    orig_shape = g.shape
    *lead, m, n = g.shape
    x = g.astype(jnp.float32).reshape(-1, m, n)
    transpose = m > n
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
        m, n = n, m
    if normalize:
        norm = jnp.linalg.norm(x, axis=(-2, -1), keepdims=True)
        x = x / (norm + eps)
    # Pad once for the whole chain (zero-pad is NS-exact, see _pad_stack) so
    # each iteration is exactly one launch with no pad/slice copies between.
    a, b, c = (float(v) for v in coeffs)
    tm_, mp, np_ = _padded_dims(m, n, tm)
    x = _pad_stack(x, mp, np_)
    if chain:
        x = _ns_chain_padded(x, a, b, c, tm_, steps, interpret)
    else:
        for _ in range(steps):
            x = _ns_iteration_padded(x, a, b, c, tm_, interpret)
    x = x[:, :m, :n]
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    return x.reshape(orig_shape).astype(orig_dtype)
