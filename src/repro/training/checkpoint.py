"""Checkpointing: flat-path .npz snapshots of params + optimizer state.

Host-side (device_get) save with sharding-agnostic restore: on load, arrays
are device_put with whatever shardings the caller provides, so a checkpoint
written on one mesh restores onto another (or onto CPU).

Sharded optimizer state (ZeRO-1): save() gathers each momentum shard into a
full host array; restore() re-applies the shardings passed as
``opt_shardings`` — derive them with ``distributed.zero1.opt_shardings`` so
the momentum lands back in its data-axis shards instead of replicated.
Sharding leaves may be NamedShardings, or ShapeDtypeStructs / arrays
carrying ``.sharding`` (e.g. the ``distributed.zero1.attach`` output).
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _as_sharding(leaf):
    """Normalize a shardings-tree leaf to something device_put accepts."""
    if isinstance(leaf, jax.sharding.Sharding):
        return leaf
    sharding = getattr(leaf, "sharding", None)
    if isinstance(sharding, jax.sharding.Sharding):
        return sharding
    raise TypeError(f"cannot interpret {type(leaf).__name__} as a sharding")


def save(path: str, params: Any, opt_state: Any = None, step: int = 0, extra: Optional[dict] = None):
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"), **_flatten(opt_state))
    meta = {"step": int(step)}
    if extra:
        meta.update(extra)
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def _unflatten_into(template, flat: dict[str, np.ndarray], shardings=None):
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    if shardings is not None:
        # Default flatten drops None subtrees in the shardings tree exactly
        # as it does in the template (masked optimizer trees rely on this
        # alignment); a per-leaf "None = default placement" is therefore
        # not expressible — omit the shardings tree instead.
        shard_leaves = [_as_sharding(s) for s in jax.tree.flatten(shardings)[0]]
        if len(shard_leaves) != len(leaves_with_path):
            raise ValueError(
                f"shardings tree has {len(shard_leaves)} leaves, template has "
                f"{len(leaves_with_path)} — restore would misalign shards"
            )
    else:
        shard_leaves = [None] * len(leaves_with_path)
    new_leaves = []
    for (path, leaf), shd in zip(leaves_with_path, shard_leaves):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"checkpoint shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        new_leaves.append(jax.device_put(arr, shd) if shd is not None else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, new_leaves)


def restore(path: str, params_template: Any, opt_template: Any = None, shardings=None, opt_shardings=None):
    """Returns (params, opt_state or None, step).

    ``opt_shardings`` must be passed when the optimizer state was sharded
    (ZeRO-1): without it the momentum restores replicated on the default
    device. Build it with ``distributed.zero1.opt_shardings(opt_template,
    params_template, mesh, zero1=True)``.
    """
    flat_p = dict(np.load(os.path.join(path, "params.npz")))
    params = _unflatten_into(params_template, flat_p, shardings)
    opt_state = None
    opt_file = os.path.join(path, "opt_state.npz")
    if opt_template is not None and os.path.exists(opt_file):
        flat_o = dict(np.load(opt_file))
        opt_state = _unflatten_into(opt_template, flat_o, opt_shardings)
    with open(os.path.join(path, "meta.json")) as f:
        step = json.load(f)["step"]
    return params, opt_state, step
