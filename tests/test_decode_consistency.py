"""Incremental decode == full forward, for every architecture family.

This is the strongest correctness check for KV caches, SSM recurrent states,
sliding windows, and cross-attention: token-by-token decoding from an empty
cache must reproduce the teacher-forced forward logits.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_cfg
from repro.configs import ARCHS
from repro.models.model import decode_step, init_cache, init_params, prefill
from repro.models.transformer import forward
from repro.serving.serve_step import cache_from_prefill


def _decode_all(cfg, params, tokens, enc_out=None, total_len=None):
    B, S = tokens.shape
    cache = init_cache(cfg, B, total_len or S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = decode_step(
            params, tokens[:, t : t + 1], cache, jnp.int32(t), cfg, encoder_out=enc_out
        )
        outs.append(lg)
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_incremental_decode_matches_forward(arch, key):
    cfg = tiny_cfg(arch, capacity_factor=100.0)  # dropless MoE for exactness
    params = init_params(key, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    enc_out = None
    kwargs = {}
    if cfg.arch_type == "audio":
        frames = 0.1 * jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
        kwargs["encoder_frames"] = frames
        from repro.models.encdec import encode

        enc_out = encode(params["encoder"], frames, cfg)
    logits_full, _ = forward(params, tokens, cfg, **kwargs)
    logits_inc = _decode_all(cfg, params, tokens, enc_out)
    err = float(jnp.max(jnp.abs(logits_full - logits_inc)))
    assert err < 1e-4, f"{arch}: {err}"


@pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x7b", "mamba2-1.3b", "hymba-1.5b"])
def test_prefill_then_decode_matches_forward(arch, key):
    """prefill(prompt) -> decode continuation must equal teacher forcing."""
    cfg = tiny_cfg(arch, capacity_factor=100.0)
    params = init_params(key, cfg)
    B, S, half = 2, 16, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_full, _ = forward(params, tokens, cfg)

    _, _, pcache = prefill(params, {"tokens": tokens[:, :half]}, cfg)
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    cache.update(cache_from_prefill(pcache, cfg, S, dtype=jnp.float32))
    outs = []
    for t in range(half, S):
        lg, cache = decode_step(params, tokens[:, t : t + 1], cache, jnp.int32(t), cfg)
        outs.append(lg)
    err = float(
        jnp.max(jnp.abs(logits_full[:, half:] - jnp.concatenate(outs, axis=1)))
    )
    assert err < 1e-4, f"{arch}: {err}"


def test_sliding_window_decode(key):
    """SWA decode: tokens beyond the window must not affect the logits."""
    cfg = tiny_cfg("mixtral-8x7b", capacity_factor=100.0)
    assert cfg.window_size == 64  # reduced window
    cfg = dataclasses.replace(cfg, window_size=4)
    params = init_params(key, cfg)
    B, S = 1, 12
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    # two prefixes differing only OUTSIDE the window of the last position
    tokens2 = tokens.at[:, 0].set((tokens[:, 0] + 1) % cfg.vocab_size)
    lg1, _ = forward(params, tokens, cfg)
    lg2, _ = forward(params, tokens2, cfg)
    # positions >= window past the change should be (nearly) unaffected
    # (MoE routing is token-local so only position-0 tokens change routing)
    diff_late = float(jnp.max(jnp.abs(lg1[:, -1] - lg2[:, -1])))
    assert diff_late < 1e-3, diff_late
