"""Optimizer-step microbenchmark (paper Sec 2.2 'Computational costs').

Times a full optimizer update over a realistic param set for AdamW / Muon /
BlockMuon / MuonBP / Dion. The Muon-family rows are measured twice — with
the shape-bucketed batched NS engine (bucketing=on, the default: one NS
chain per distinct unit shape) and with per-leaf dispatch (bucketing=off) —
so the engine win shows up as a column-wise A/B on identical numerics. The
backend column records the NS execution backend (jnp on CPU; the pallas
interpret path is a correctness artifact benchmarked in ns_cost).

The shard_map-engine full step is additionally measured once per execution
schedule (``schedule`` column: barrier vs pipelined) on the local 1-device
mesh — identical numerics and zero collectives at this scale, so the row
pair isolates the pipeline body's dispatch overhead; the multi-device
byte-level A/B lives in comm_volume."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit_stats
from repro.configs import get_config
from repro.core import adamw, block_muon, combine, dion, label_tree, muon, muon_full
from repro.core.blocking import BlockSpec2D
from repro.models.model import init_params


def run(quick: bool = False) -> list[str]:
    cfg = get_config("muonbp-960m").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    labels = label_tree(params)
    blocks = jax.tree.map(
        lambda p: BlockSpec2D(1, 4 if p.ndim >= 2 and p.shape[-1] % 4 == 0 else 1)
        if p.ndim >= 2 else None,
        params,
    )

    rows = []
    n_params = sum(int(p.size) for p in jax.tree.leaves(params))
    variants = [
        ("adamw", None, "block", "-", "-"),
        ("muon_full", lambda b: muon_full(1e-3, bucketing=b, ns_backend="jnp"),
         "full", "jnp", None),
        ("blockmuon", lambda b: block_muon(1e-3, block_specs=blocks, bucketing=b,
                                           ns_backend="jnp"), "block", "jnp", None),
        ("muonbp_block_phase", lambda b: muon(1e-3, block_specs=blocks, bucketing=b,
                                              ns_backend="jnp"), "block", "jnp", None),
        ("dion_r32", lambda b: dion(1e-3, rank=32), "block", "-", "-"),
    ]
    for name, make, phase, backend, bucket_col in variants:
        bucket_modes = (
            [(bucket_col, None)]
            if bucket_col is not None
            else [("on", True), ("off", False)]
        )
        for bucket_label, bucketing in bucket_modes:
            if make is None:
                opt = combine(
                    {"adamw": adamw(1e-3)}, jax.tree.map(lambda _: "adamw", labels)
                )
            else:
                matrix_opt = make(bucketing) if bucketing is not None else make(True)
                opt = combine({"muon": matrix_opt, "adamw": adamw(1e-3)}, labels)
            state = opt.init(params)

            @jax.jit
            def step(g, s, p):
                return opt.update(g, s, p, phase)

            st = timeit_stats(step, grads, state, params, warmup=1, iters=3,
                              name=f"opt_step_{name}")
            rows.append(
                row(f"opt_step_{name}", st["median_us"],
                    f"{n_params/1e6:.1f}M_params",
                    backend=backend, bucketing=bucket_label,
                    p50_us=f"{st['p50_us']:.1f}", p95_us=f"{st['p95_us']:.1f}")
            )

    # shard_map engine full step, once per schedule (barrier vs pipelined).
    from jax.sharding import PartitionSpec as P

    from repro.distributed import make_engine

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pspecs = jax.tree.map(lambda p: P(*(None,) * p.ndim), params)
    for sched in ("barrier", "pipelined"):
        engine = make_engine(params, pspecs, mesh)
        matrix_opt = muon(1e-3, block_specs=blocks, comm=engine,
                          ns_backend="jnp", full_schedule=sched)
        opt = combine({"muon": matrix_opt, "adamw": adamw(1e-3)}, labels)
        state = opt.init(params)

        @jax.jit
        def estep(g, s, p, _opt=opt):
            return _opt.update(g, s, p, "full")

        st = timeit_stats(estep, grads, state, params, warmup=1, iters=3,
                          name="opt_step_muonbp_full_engine")
        rows.append(
            row("opt_step_muonbp_full_engine", st["median_us"],
                f"{n_params/1e6:.1f}M_params",
                backend="jnp", bucketing="on", engine="shard_map",
                schedule=sched,
                p50_us=f"{st['p50_us']:.1f}", p95_us=f"{st['p95_us']:.1f}")
        )
    return rows
