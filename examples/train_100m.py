"""End-to-end driver: pretrain a ~100M-param Llama-style model with MuonBP.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--optimizer muonbp]

This is the assignment's end-to-end example ("train ~100M model for a few
hundred steps"): real config, WSD schedule, periodic checkpointing, block/
full phase scheduling, throughput + loss logging. On CPU expect a few
seconds per step; on a TPU slice pass --mesh-model to enable tensor
parallelism (the same code path the dry-run exercises at 16x16).
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import adamw, combine, label_tree, muon
from repro.core.muon import phase_for_step
from repro.core.schedule import wsd
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models.model import init_params
from repro.sharding import specs as sh
from repro.training import checkpoint
from repro.training.train_step import init_train_state, make_train_step_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--period", type=int, default=5)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--log-file", default="/tmp/repro_100m_log.json")
    args = ap.parse_args()

    # ~100M params: 10 layers, d=768, vocab 32k (reduced from muonbp-960m).
    import dataclasses

    cfg = dataclasses.replace(
        get_config("muonbp-960m"),
        num_layers=10, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
        d_ff=3072, vocab_size=32768,
    )

    mesh = make_local_mesh(model=args.mesh_model)
    ctx = sh.make_ctx(cfg, mesh, global_batch=args.batch)

    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.num_layers}L d={cfg.d_model} vocab={cfg.padded_vocab} "
          f"-> {n_params/1e6:.1f}M params")

    pspecs = sh.param_specs(params, cfg, mesh)
    params = jax.device_put(params, sh.named(mesh, pspecs))
    labels = label_tree(params)
    bspecs = jax.tree.map(
        lambda l, b: b if l == "muon" else None,
        labels, sh.block_specs_for(params, pspecs, mesh),
    )

    schedule = wsd(args.lr, args.steps, warmup_steps=10, decay_frac=0.2)
    optimizer = combine(
        {"muon": muon(schedule, schedule, period=args.period, block_specs=bspecs,
                      weight_decay=0.1),
         "adamw": adamw(wsd(args.lr * 0.4, args.steps, decay_frac=0.2),
                        weight_decay=0.1)},
        labels,
    )

    state = init_train_state(params, optimizer)
    fns = make_train_step_fns(cfg, optimizer, ctx)
    pipe = iter(SyntheticLM(cfg, args.batch, args.seq, seed=0))

    log = []
    t_start = time.time()
    tokens_seen = 0
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        phase = phase_for_step(step, args.period)
        t0 = time.time()
        state, metrics = fns[phase](state, batch)
        loss = float(metrics["loss"])  # blocks
        dt = time.time() - t0
        tokens_seen += args.batch * args.seq
        if step % 10 == 0 or step == args.steps - 1:
            rec = {"step": step, "phase": phase, "loss": round(loss, 4),
                   "step_s": round(dt, 3),
                   "tok_per_s": round(args.batch * args.seq / dt)}
            log.append(rec)
            print(json.dumps(rec), flush=True)
        if step and step % 100 == 0:
            checkpoint.save(args.checkpoint_dir, state.params, state.opt_state, step)
            print(f"checkpointed at step {step}")

    checkpoint.save(args.checkpoint_dir, state.params, state.opt_state, args.steps)
    wall = time.time() - t_start
    summary = {"params_m": round(n_params / 1e6, 1), "steps": args.steps,
               "final_loss": log[-1]["loss"], "wall_s": round(wall, 1),
               "tokens": tokens_seen}
    print("summary:", json.dumps(summary))
    with open(args.log_file, "w") as f:
        json.dump({"summary": summary, "log": log}, f, indent=1)


if __name__ == "__main__":
    main()
