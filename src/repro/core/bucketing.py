"""Shape-bucketed Newton-Schulz execution over a parameter pytree.

Per-leaf NS dispatch (one orthogonalization chain per parameter) is the
optimizer's structural bottleneck: a transformer has dozens of matrices but
only a handful of distinct matrix shapes, so launching one NS chain per leaf
pays dispatch overhead and runs skinny matmuls where one fat batched matmul
would do. This module groups every NS unit in the update — whole matrices
(full phase / unblocked leaves) or shard-local blocks (block phase) — by its
exact unit shape (and dtype), packs each group into one batched tensor, runs
*one* batched orthogonalization per bucket, and scatters the results back to
the original leaves. Numerics are identical to the per-leaf path: NS touches
each unit independently (the batched chain maps over the leading dims), so
bucketing only changes execution shape, not math.

Two packing modes, chosen by the caller per phase:

  * ``mode="concat"`` — flatten each leaf's leading dims and concatenate all
    units along the stack axis. Maximum batching (different unit counts
    merge). Used on FULL steps: the full orthogonalization gathers shards
    anyway, and a fatter stack also feeds the ``layer_shard`` CommOp better.
  * ``mode="stack"`` — bucket by the *entire* blocked shape and stack
    members along a NEW leading axis. Concatenating the block dim of
    differently-owned shard-local blocks would force GSPMD to all-gather
    them (measured: it reintroduced the Muon gather on block steps);
    stacking on a fresh axis keeps every operand's sharding intact, so
    BLOCK steps stay zero-collective while still coalescing dispatches.

Buckets are keyed by exact orientation: an ``(m, n)`` matrix and its
``(n, m)`` sibling form two buckets. Merging orientations via a pre-
transpose (``Orth(X^T) = Orth(X)^T``) was measured and rejected: the
transpose must materialize a copy of every tall unit before packing, which
costs more than the one extra dispatch — the batched orthogonalizer already
transposes the whole bucket internally, where XLA fuses it into the first
Gram matmul.

This module owns the *mechanics* of bucketing — planning (:func:`plan_leaf`,
:func:`plan_buckets`), packing (:func:`pack_bucket`) and unpacking
(:func:`unpack_bucket`). The *decision* of which leaves form which buckets
per phase is compiled once into an :class:`repro.core.program.UpdateProgram`
whose interpreter calls these helpers; :func:`bucketed_orthogonalize` remains
the standalone leaf-level utility for tests and ad-hoc callers.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import blocking

# concat mode: (unit rows, unit cols, dtype). stack mode: (blocked shape, dtype).
BucketKey = tuple


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """How one leaf maps into its bucket (enough to invert the packing)."""

    key: BucketKey
    units: int                                 # flattened units (concat mode)
    spec: Optional[blocking.BlockSpec2D]       # block partitioning applied
    block_shape: tuple                         # shape after blocking


def plan_leaf(shape: tuple, dtype, spec, mode: str) -> LeafPlan:
    """Compute a leaf's bucket plan from shape/dtype alone (no data)."""
    applied = None
    if spec is not None and spec.num_blocks > 1:
        *lead, m, n = shape
        if m % spec.r or n % spec.c:
            raise ValueError(f"blocks {spec} do not divide matrix {(m, n)}")
        shape = (*lead, spec.num_blocks, m // spec.r, n // spec.c)
        applied = spec
    block_shape = tuple(shape)
    units = 1
    for d in block_shape[:-2]:
        units *= d
    dt = str(jnp.dtype(dtype).name)
    if mode == "concat":
        key: BucketKey = (block_shape[-2], block_shape[-1], dt)
    elif mode == "stack":
        key = (block_shape, dt)
    else:
        raise ValueError(f"mode must be 'concat' or 'stack', got {mode!r}")
    return LeafPlan(key=key, units=units, spec=applied, block_shape=block_shape)


def partition_leaf(leaf: jax.Array, plan: LeafPlan) -> jax.Array:
    """Apply the plan's logical block partitioning (identity when unblocked)."""
    x = leaf
    if plan.spec is not None:
        x = blocking.partition_blocks(x, plan.spec)
    return x


def restore_leaf(x: jax.Array, plan: LeafPlan) -> jax.Array:
    """Inverse of :func:`partition_leaf` plus the bucket-shape reshape."""
    x = x.reshape(plan.block_shape)
    if plan.spec is not None:
        x = blocking.unpartition_blocks(x, plan.spec)
    return x


def pack_bucket(parts: Sequence[jax.Array], mode: str) -> jax.Array:
    """Pack already-partitioned bucket members into one batched tensor.

    Single-member buckets pass through untouched (the batched orthogonalizer
    maps over whatever leading dims the member already has) — this keeps the
    degenerate ``bucketing=False`` program bitwise-identical to per-leaf
    dispatch. Multi-member buckets either concat flattened units along the
    stack axis (``"concat"``) or stack on a new leading axis (``"stack"``).
    """
    if len(parts) == 1:
        return parts[0]
    if mode == "concat":
        return jnp.concatenate(
            [p.reshape(-1, p.shape[-2], p.shape[-1]) for p in parts], axis=0
        )
    return jnp.stack(parts, axis=0)


def unpack_bucket(
    packed: jax.Array, plans: Sequence[LeafPlan], mode: str
) -> list[jax.Array]:
    """Invert :func:`pack_bucket`: scatter the batched result per member."""
    if len(plans) == 1:
        return [restore_leaf(packed, plans[0])]
    if mode == "concat":
        out, offset = [], 0
        for plan in plans:
            out.append(restore_leaf(packed[offset : offset + plan.units], plan))
            offset += plan.units
        return out
    return [restore_leaf(packed[pos], plan) for pos, plan in enumerate(plans)]


def plan_buckets(
    leaves: Sequence,
    specs: Sequence[Optional[blocking.BlockSpec2D]],
    mode: str = "concat",
) -> dict[BucketKey, list[int]]:
    """Bucket key -> leaf indices, without touching data (for tests/benches).

    ``leaves`` may be arrays or anything with ``.shape``/``.dtype`` (e.g.
    ``jax.ShapeDtypeStruct``).
    """
    buckets: dict[BucketKey, list[int]] = {}
    for idx, (leaf, spec) in enumerate(zip(leaves, specs)):
        plan = plan_leaf(tuple(leaf.shape), leaf.dtype, spec, mode)
        buckets.setdefault(plan.key, []).append(idx)
    return buckets


def bucketed_orthogonalize(
    leaves: Sequence[jax.Array],
    specs: Sequence[Optional[blocking.BlockSpec2D]],
    orth: Callable[[jax.Array], jax.Array],
    mode: str = "concat",
) -> list[jax.Array]:
    """Orthogonalize every leaf with one ``orth`` call per shape bucket.

    Args:
      leaves: arrays with ndim >= 2 (trailing dims are the matrix).
      specs: per-leaf :class:`blocking.BlockSpec2D` or None; a spec with
        ``num_blocks > 1`` means the leaf's NS units are its shard-local
        blocks (pass all-None on full-orthogonalization steps).
      orth: batched orthogonalizer applied once per bucket; receives a
        stacked tensor whose trailing two dims are the matrix.
      mode: packing strategy, see module docstring ("concat" for full
        steps, "stack" for sharding-preserving block steps).

    Returns the orthogonalized leaves, original shapes and order.
    """
    plans = [
        plan_leaf(tuple(leaf.shape), leaf.dtype, spec, mode)
        for leaf, spec in zip(leaves, specs)
    ]
    buckets: dict[BucketKey, list[int]] = {}
    for idx, plan in enumerate(plans):
        buckets.setdefault(plan.key, []).append(idx)

    results: list[Optional[jax.Array]] = [None] * len(leaves)
    for members in buckets.values():
        parts = [partition_leaf(leaves[i], plans[i]) for i in members]
        orthed = orth(pack_bucket(parts, mode))
        for i, out in zip(members, unpack_bucket(orthed, [plans[i] for i in members], mode)):
            results[i] = out
    return results  # type: ignore[return-value]
